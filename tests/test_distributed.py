"""Distribution tests requiring >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (jax locks the device
count at first init, so the main pytest process stays single-device)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_in_subprocess(body: str, devices: int = 8) -> dict:
    prog = textwrap.dedent(f"""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("RESULT::" + json.dumps(out))
    """)
    res = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line.split("RESULT::", 1)[1])


def test_int8_ring_allreduce_with_error_feedback():
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compressed_allreduce, init_compression
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        # Distinct per-device gradients: feed the function a sharded array
        # whose shards differ.
        g_global = rng.normal(size=(8, 64)).astype(np.float32)
        expect = g_global.mean(axis=0)
        sh = jax.sharding.NamedSharding(mesh, P("data", None))
        g = jax.device_put(g_global, sh)
        grads = {"w": g}
        state = init_compression(grads)

        # shard_map consumes the leading axis as the per-device shard.
        import repro.distributed.compression as comp
        def leaf(gl, el):
            x = gl.reshape(-1) + el.reshape(-1)
            pad = (-x.shape[0]) % 8
            xp = jnp.pad(x, (0, pad))
            red = comp._ring_allreduce_int8(xp, "data", 8)[: x.shape[0]]
            return red.reshape(gl.shape), (x - red).reshape(gl.shape)
        f = jax.jit(jax.shard_map(leaf, mesh=mesh,
                                  in_specs=(P("data", None), P("data", None)),
                                  out_specs=(P("data", None), P("data", None)),
                                  check_vma=False))
        red, err = f(g, state.error["w"])
        red_np = np.asarray(red)
        # Every device row holds the (approximate) mean.
        err_vs_mean = np.abs(red_np - expect[None, :]).max()
        # int8 quantization error bound: a few scale quanta per hop.
        scale = np.abs(g_global).max() / 127.0
        out = {"err": float(err_vs_mean), "bound": float(scale * 16),
               "resid": float(np.abs(np.asarray(err)).max())}
    """)
    assert out["err"] <= out["bound"], out
    assert out["resid"] > 0.0  # error feedback captured the lost bits


def test_dks_sharded_matches_single_device():
    """The DKS superstep loop under an 8-device mesh produces identical
    top-K weights to the single-device run (SPMD correctness)."""
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.core import DKSConfig, run_dks
        from repro.graph.generators import random_weighted_graph
        from repro.launch.mesh import sharding_tree

        g = random_weighted_graph(64, 160, seed=5)
        dg = g.to_device(pad_nodes_to=64, pad_edges_to=((g.n_edges_sym+7)//8)*8)
        masks = np.zeros((3, dg.v_pad), bool)
        masks[0, 3] = masks[1, 17] = masks[2, 41] = True
        cfg = DKSConfig(m=3, k=2, max_supersteps=48)

        single = run_dks(dg, jnp.asarray(masks), cfg)

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        with jax.set_mesh(mesh):
            import dataclasses
            sharded_graph = jax.device_put(
                dg, jax.tree_util.tree_map(
                    lambda _: jax.sharding.NamedSharding(mesh, P("data")),
                    dg))
            sharded = run_dks(sharded_graph, jnp.asarray(masks), cfg)
        out = {
            "single": np.asarray(single.topk_w).tolist(),
            "sharded": np.asarray(sharded.topk_w).tolist(),
            "single_steps": int(single.step),
            "sharded_steps": int(sharded.step),
        }
    """)
    assert out["single"] == out["sharded"], out
    assert out["single_steps"] == out["sharded_steps"]


def test_dks_frontier_relax_matches_dense():
    """Frontier-compressed sharded DKS == dense single-device DKS when the
    frontier cap is not hit; overflow raises budget_hit instead of silently
    dropping messages."""
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.core import DKSConfig, run_dks
        from repro.core.dks_sharded import (
            pack_frontier_graph, run_dks_frontier)
        from repro.graph.generators import random_weighted_graph
        from repro.launch.mesh import sharding_tree

        g = random_weighted_graph(64, 160, seed=5)
        dg = g.to_device(pad_nodes_to=64)
        masks = np.zeros((3, 64), bool)
        masks[0, 3] = masks[1, 17] = masks[2, 41] = True
        cfg = DKSConfig(m=3, k=2, max_supersteps=48, frontier_frac=1.0)

        dense = run_dks(dg, jnp.asarray(masks), cfg)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        fg = pack_frontier_graph(g, n_shards=8)
        with jax.set_mesh(mesh):
            fg = jax.device_put(fg, jax.tree_util.tree_map(
                lambda _: jax.sharding.NamedSharding(
                    mesh, P(("data", "model"))), fg))
            m2 = np.zeros((3, fg.v_pad), bool)
            m2[:, :64] = masks
            frontier = run_dks_frontier(fg, jnp.asarray(m2), cfg)

            # Tiny cap -> overflow -> budget_hit (paper Sec. 5.4 semantics).
            cfg_tiny = DKSConfig(m=3, k=2, max_supersteps=48,
                                 frontier_frac=0.01)
            capped = run_dks_frontier(fg, jnp.asarray(m2), cfg_tiny)
        out = {
            "dense": np.asarray(dense.topk_w).tolist(),
            "frontier": np.asarray(frontier.topk_w).tolist(),
            "budget_hit": bool(capped.budget_hit),
        }
    """)
    assert out["dense"] == out["frontier"], out
    assert out["budget_hit"] is True


def test_lm_train_step_sharded_runs():
    """A reduced LM train step executes correctly under a (2,4) mesh with
    the production sharding specs (numerics, not just lowering)."""
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import lm as lm_lib
        from repro.models import transformer as tfm
        from repro.optim import AdamWConfig
        from repro.launch.mesh import sharding_tree
        import dataclasses as dc

        cfg = get_arch("chatglm3-6b").config.smoke()
        cfg = dc.replace(cfg, d_model=64, n_heads=4, n_kv_heads=2, vocab=256)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        b = tfm.build(cfg, tp=4)
        with jax.set_mesh(mesh):
            state = lm_lib.init_train_state(jax.random.PRNGKey(0), b)
            specs = tfm.param_specs(b)
            from repro.optim import OptState
            st_spec = lm_lib.TrainState(
                params=specs,
                opt=OptState(mu=specs, nu=specs, count=P()), step=P())
            sh = sharding_tree(mesh, st_spec)
            state = jax.device_put(state, sh)
            step = jax.jit(lm_lib.make_train_step(
                b, AdamWConfig(), attn_impl="naive"), donate_argnums=0)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)
            batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
            losses = []
            for _ in range(3):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        out = {"losses": losses}
    """)
    ls = out["losses"]
    assert all(np.isfinite(l) for l in ls), ls
    assert ls[-1] < ls[0], f"loss did not improve: {ls}"


import numpy as np  # noqa: E402
