"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
shape/dtype sweeps + hypothesis property tests (deliverable c)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import INF
from repro.core.semiring import sorted_unique_k
from repro.kernels.subset_combine.ops import subset_combine
from repro.kernels.subset_combine.ref import subset_combine_ref
from repro.kernels.segment_minplus.kernel import padded_topk
from repro.kernels.segment_minplus.ref import padded_topk_ref
from repro.kernels.segment_minplus.ops import (
    padded_csr_from_graph, segment_minplus_padded)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref

RNG = np.random.default_rng(0)


def random_table(v, m, k, finite_frac=0.5, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(1, 20, size=(v, 1 << m, k)).astype(np.float32)
    mask = rng.random((v, 1 << m, k)) > finite_frac
    s[mask] = INF
    # Make rows sorted-unique (the lattice invariant).
    s = np.array(sorted_unique_k(jnp.asarray(s), k))
    s[:, 0, :] = INF
    return jnp.asarray(s)


# --------------------------------------------------------------------------
# subset_combine
# --------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,v", [(2, 1, 8), (2, 2, 32), (3, 2, 8),
                                   (4, 2, 64), (4, 4, 16), (5, 2, 8)])
def test_subset_combine_matches_ref(m, k, v):
    s = random_table(v, m, k, seed=m * 100 + k)
    got = subset_combine(s, m, interpret=True, block_v=8)
    want = subset_combine_ref(s, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_subset_combine_single_pass_closure():
    """The kernel reaches closure in ONE pass (in-kernel popcount sweep);
    a second application must be a no-op (idempotence)."""
    s = random_table(16, 4, 2, seed=7)
    once = subset_combine(s, 4, interpret=True, block_v=8)
    twice = subset_combine(once, 4, interpret=True, block_v=8)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 4), k=st.integers(1, 3), seed=st.integers(0, 99))
def test_subset_combine_hypothesis(m, k, seed):
    s = random_table(8, m, k, seed=seed)
    got = subset_combine(s, m, interpret=True, block_v=8)
    want = subset_combine_ref(s, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# --------------------------------------------------------------------------
# segment_minplus (padded-CSR reduce)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("vv,c,f,k", [(8, 16, 4, 2), (16, 64, 16, 2),
                                      (8, 128, 16, 4), (24, 32, 8, 1)])
def test_padded_topk_matches_ref(vv, c, f, k):
    rng = np.random.default_rng(vv + c)
    cand = rng.integers(1, 30, size=(vv, c, f)).astype(np.float32)
    cand[rng.random((vv, c, f)) > 0.6] = INF
    cand = jnp.asarray(cand)
    got = padded_topk(cand, k, block_v=8, interpret=True)
    want = padded_topk_ref(cand, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_segment_minplus_padded_vs_engine_relax():
    """Full padded-CSR relax (gather + Pallas reduce + hub merge) equals
    the engine's segment relax."""
    from repro.core import dks as dks_mod
    from repro.core.dks import DKSConfig
    from repro.graph.generators import random_weighted_graph

    g = random_weighted_graph(40, 120, seed=3)
    dg = g.to_device()
    m, k = 3, 2
    cfg = DKSConfig(m=m, k=k)
    rng = np.random.default_rng(0)
    S = random_table(dg.v_pad, m, k, seed=11)
    changed = jnp.asarray(rng.random(dg.v_pad) > 0.3)

    want = dks_mod.relax(dg, S, changed, cfg)

    deg = np.diff(g.indptr)
    src = np.repeat(np.arange(g.n_nodes), deg).astype(np.int32)
    dst = g.indices.astype(np.int32)
    w = g.ew.astype(np.float32)
    csr = padded_csr_from_graph(src, dst, w, g.n_nodes, dmax=8)
    got = segment_minplus_padded(S, csr, changed, k, dg.v_pad,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_padded_csr_hub_split():
    """A node with degree > dmax gets multiple virtual rows."""
    src = np.asarray([1, 2, 3, 4, 5], np.int32)
    dst = np.zeros(5, np.int32)
    w = np.ones(5, np.float32)
    csr = padded_csr_from_graph(src, dst, w, 6, dmax=2)
    rows_for_0 = np.sum(np.asarray(csr.real_of) == 0)
    assert rows_for_0 >= 3  # ceil(5/2) = 3 rows plus padding rows map to 0


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,sq,skv,hq,hkv,dh", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 4, 2, 64),      # GQA g=2
    (1, 128, 384, 8, 1, 128),     # MQA, longer kv
    (2, 100, 100, 4, 4, 64),      # non-multiple lengths (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, skv, hq, hkv, dh, dtype):
    rng = np.random.default_rng(b * sq)
    q = jnp.asarray(rng.normal(size=(b, sq, hq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, hkv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, hkv, dh)), dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_flash_attention_decode_offset():
    """q_offset masking: decoding position 37 of a 64-long cache."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 8, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=37, interpret=True)
    want = attention_ref(q, k, v, causal=True, q_offset=37)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------------------------------
# embedding bag
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,nnz,v,d,mode", [
    (8, 4, 100, 16, "sum"), (16, 8, 1000, 32, "mean"),
    (5, 3, 50, 8, "sum"),   # non-multiple batch (padding path)
])
def test_embedding_bag_matches_ref(b, nnz, v, d, mode):
    rng = np.random.default_rng(b * nnz)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = rng.integers(-1, v, size=(b, nnz)).astype(np.int32)
    got = embedding_bag(table, jnp.asarray(ids), None, mode=mode,
                        interpret=True)
    want = embedding_bag_ref(table, jnp.asarray(ids), None, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


def test_embedding_bag_weights():
    table = jnp.eye(4, dtype=jnp.float32)
    ids = jnp.asarray([[0, 1]], jnp.int32)
    w = jnp.asarray([[2.0, 3.0]], jnp.float32)
    got = embedding_bag(table, ids, w, mode="sum", interpret=True)
    np.testing.assert_allclose(np.asarray(got[0]), [2.0, 3.0, 0.0, 0.0])


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 12), nnz=st.integers(1, 6), seed=st.integers(0, 50))
def test_embedding_bag_hypothesis(b, nnz, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(30, 8)).astype(np.float32))
    ids = rng.integers(-1, 30, size=(b, nnz)).astype(np.int32)
    got = embedding_bag(table, jnp.asarray(ids), None, interpret=True)
    want = embedding_bag_ref(table, jnp.asarray(ids), None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
