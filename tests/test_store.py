"""Graph store & ingestion tests: artifact roundtrip query parity (dense
and 1-shard sharded, bit-identical), InvertedIndex persistence contracts,
checksum / format-version validation, cache-token safety across artifact
builds, streaming readers, and the rmat_edges true-count fix."""

import json

import numpy as np
import pytest

from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import lod_like_graph, rmat_edges
from repro.graph.index import InvertedIndex
from repro.serve import ResultCache
from repro.store import (
    ArtifactError,
    ChecksumError,
    FormatVersionError,
    StreamIngestor,
    from_graph,
    ingest_ntriples,
    ingest_tsv,
    open_artifact,
    write_artifact,
    write_tsv,
)


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g, tokens = lod_like_graph(600, 1800, seed=11, vocab=120)
    result = from_graph(g, tokens=tokens, edges_requested=1800)
    path = tmp_path_factory.mktemp("store") / "artifact"
    artifact = write_artifact(path, result.graph, result.index,
                              tau=result.tau,
                              stats=result.stats.as_dict())
    return g, tokens, result, artifact


def mid_df_queries(index, n=4, ms=(2, 3)):
    toks = [t for t in sorted(index.vocabulary(), key=index.df)
            if 2 <= index.df(t) <= 60]
    queries = []
    for i in range(n):
        m = ms[i % len(ms)]
        q = toks[i * 2: i * 2 + m]
        assert len(q) == m
        queries.append(q)
    return queries


def assert_results_identical(ra, rb, query):
    np.testing.assert_array_equal(
        ra.weights, rb.weights,
        err_msg=f"weights diverged for {query!r}")
    np.testing.assert_array_equal(ra.roots, rb.roots)
    assert ra.supersteps == rb.supersteps
    assert ra.spa == rb.spa and ra.spa_ratio == rb.spa_ratio
    assert (ra.done, ra.budget_hit, ra.capped) == \
        (rb.done, rb.budget_hit, rb.capped)
    assert ra.msgs_bfs == rb.msgs_bfs and ra.msgs_deep == rb.msgs_deep


@pytest.mark.parametrize("partition", ["single", "sharded"])
def test_artifact_roundtrip_bit_identical(setup, partition):
    """graph -> artifact -> mmap-load -> engine gives bit-identical
    QueryResults vs the in-memory build, dense and 1-shard sharded."""
    g, tokens, result, artifact = setup
    policy = ExecutionPolicy(
        max_supersteps=32, partition=partition,
        n_shards=1 if partition == "sharded" else None,
        frontier_frac=1.0 if partition == "sharded" else 0.25)
    e_mem = QueryEngine.build(g, index=result.index, policy=policy)
    e_art = QueryEngine.build(artifact=open_artifact(artifact.path),
                              policy=policy)
    assert e_art.n_nodes == e_mem.n_nodes
    assert e_art.n_edges == e_mem.n_edges
    for q in mid_df_queries(result.index):
        ra = e_mem.query(q, k=2, extract=False)
        rb = e_art.query(q, k=2, extract=False)
        assert_results_identical(ra, rb, q)
    # Forced-stop bounds survive the roundtrip too (superstep cap).
    q = mid_df_queries(result.index)[0]
    ra = e_mem.query(q, k=1, extract=False, max_supersteps=2)
    rb = e_art.query(q, k=1, extract=False, max_supersteps=2)
    assert_results_identical(ra, rb, q)
    # Answer-tree extraction reads the host graph (CSR) — the mmapped
    # arrays must serve it identically.
    ra = e_mem.query(q, k=2)
    rb = e_art.query(q, k=2)
    assert [a.weight for a in ra.answers] == [a.weight for a in rb.answers]
    assert [a.root for a in ra.answers] == [a.root for a in rb.answers]


def test_index_persistence_token_matrix(setup):
    """from_token_matrix indexes survive save/load: identical lookup /
    df / missing_tokens, and the on_missing='raise' KeyError contract."""
    _, tokens, result, artifact = setup
    orig = result.index
    loaded = open_artifact(artifact.path).index()
    assert sorted(loaded.vocabulary()) == sorted(orig.vocabulary())
    for tok in orig.vocabulary():
        np.testing.assert_array_equal(loaded.lookup(tok), orig.lookup(tok))
        assert loaded.df(tok) == orig.df(tok)
    missing = 10_000  # out of vocab
    assert loaded.missing_tokens([missing]) == [missing]
    assert len(loaded.lookup(missing)) == 0
    q = [orig.vocabulary()[0], missing]
    with pytest.raises(KeyError):
        loaded.keyword_masks(q, 600)
    masks = loaded.keyword_masks(q, 600, v_pad=640, on_missing="ignore")
    np.testing.assert_array_equal(
        masks, orig.keyword_masks(q, 600, v_pad=640, on_missing="ignore"))


def test_index_persistence_labels(tmp_path):
    """from_labels (string-token) indexes survive save/load."""
    labels = ["paris piano", "piano bar", "tour eiffel paris", "", "bar"]
    src, dst = [0, 1, 2, 3], [1, 2, 3, 4]
    from repro.graph.structure import build_graph
    g = build_graph(src, dst, 5, labels=labels)
    orig = InvertedIndex.from_labels(labels)
    art = write_artifact(tmp_path / "a", g, orig)
    loaded = open_artifact(art.path, verify="full").index()
    assert sorted(loaded.vocabulary()) == sorted(orig.vocabulary())
    for tok in orig.vocabulary():
        np.testing.assert_array_equal(loaded.lookup(tok), orig.lookup(tok))
    assert loaded.missing_tokens(["paris", "nope"]) == ["nope"]
    with pytest.raises(KeyError):
        loaded.keyword_masks(["nope"], 5)
    # Labels text itself roundtrips (offsets + utf-8 blob).
    assert open_artifact(art.path).labels() == labels


def test_artifact_validation_errors(tmp_path, setup):
    g, tokens, result, _ = setup
    art = write_artifact(tmp_path / "a", result.graph, result.index)
    # Overwrite protection.
    with pytest.raises(ArtifactError):
        write_artifact(tmp_path / "a", result.graph, result.index)
    # Missing artifact.
    with pytest.raises(ArtifactError):
        open_artifact(tmp_path / "nope")
    # Corrupted buffer: meta open succeeds, full verify raises.
    buf = art.path / "post_nodes.npy"
    raw = bytearray(buf.read_bytes())
    raw[-1] ^= 0xFF
    buf.write_bytes(bytes(raw))
    open_artifact(art.path)  # header/shape still fine
    with pytest.raises(ChecksumError):
        open_artifact(art.path, verify="full")
    # Format-version mismatch is its own clear error.
    manifest = json.loads((art.path / "manifest.json").read_text())
    manifest["format_version"] = 99
    (art.path / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(FormatVersionError):
        open_artifact(art.path)
    # Not an artifact manifest at all.
    manifest["format_version"] = 1
    manifest["magic"] = "something-else"
    (art.path / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(FormatVersionError):
        open_artifact(art.path)


def test_cache_token_keyed_on_artifact_hash(tmp_path, setup):
    """ISSUE acceptance: a ResultCache keyed through cache_token MISSES
    when the engine is rebuilt from a different artifact (content hash in
    the token) — and HITS across rebuilds from the same artifact."""
    g, tokens, result, artifact = setup
    g2, tokens2 = lod_like_graph(600, 1800, seed=12, vocab=120)
    res2 = from_graph(g2, tokens=tokens2)
    art2 = write_artifact(tmp_path / "other", res2.graph, res2.index)
    assert art2.content_hash != artifact.content_hash

    e_a = QueryEngine.build(artifact=open_artifact(artifact.path))
    e_a2 = QueryEngine.build(artifact=open_artifact(artifact.path))
    e_b = QueryEngine.build(artifact=art2)
    q = mid_df_queries(result.index, n=1)[0]
    assert e_a.version == f"artifact:{artifact.content_hash}"
    assert e_a.graph_hash == artifact.content_hash

    cache = ResultCache(capacity=8)
    cache.put(e_a.cache_token(q, 1), "answer-from-artifact-A")
    # Same artifact, fresh build (e.g. serve restart): the token is
    # stable, the cached answer is still valid and served.
    assert cache.get(e_a2.cache_token(q, 1)) == "answer-from-artifact-A"
    # Different artifact: token differs, the cache must miss.
    assert cache.get(e_b.cache_token(q, 1)) is None
    # In-memory builds keep monotone versions: always a fresh token.
    e_mem = QueryEngine.build(g, index=result.index)
    assert cache.get(e_mem.cache_token(q, 1)) is None


def test_ntriples_reader(tmp_path):
    nt = tmp_path / "d.nt"
    nt.write_text(
        '<http://ex.org/Alice_Smith> <http://ex.org/p#knows> '
        '<http://ex.org/Bob> .\n'
        '<http://ex.org/Bob> <http://ex.org/p#likes> "piano \\"jazz\\""'
        '@en .\n'
        '# a comment line\n'
        '\n'
        '<http://ex.org/Bob> <http://ex.org/p#knows> '
        '<http://ex.org/Carol> .\n'
        'this line is malformed\n'
        '<http://ex.org/Loop> <http://ex.org/p#self> '
        '<http://ex.org/Loop> .\n')
    res = ingest_ntriples(nt)
    st = res.stats
    assert st.lines_read == 7
    assert st.statements == 4
    assert st.malformed_lines == 1
    assert st.self_loops_dropped == 1
    assert st.edges_directed == 3
    assert st.n_predicates == 3
    assert res.graph.n_nodes == 5  # Alice, Bob, literal, Carol, Loop
    # URI local names tokenize into keywords; literals keep their text.
    assert res.index.df("alice") == 1
    assert res.index.df("piano") == 1
    engine = QueryEngine.build(res.graph, index=res.index)
    r = engine.query(["alice", "carol"], k=1, extract=False)
    assert r.weights[0] == 2.0  # alice -(1)- bob -(1)- carol
    with pytest.raises(ValueError):
        ingest_ntriples(nt, on_error="raise")


def test_tsv_reader_and_chunking(tmp_path):
    src, dst = rmat_edges(300, 900, seed=5)
    tsv = tmp_path / "e.tsv"
    assert write_tsv(tsv, src, dst) == 900
    # Tiny chunks + spilling: identical result, bounded resident memory.
    res = ingest_tsv(tsv, chunk_edges=128,
                     spill_dir=tmp_path / "spill")
    assert res.stats.edges_directed == 900
    assert res.stats.chunks >= 7
    assert res.stats.spilled_chunks > 0
    res_big = ingest_tsv(tsv)
    assert res_big.stats.spilled_chunks == 0
    np.testing.assert_array_equal(res.graph.indptr, res_big.graph.indptr)
    np.testing.assert_array_equal(res.graph.indices,
                                  res_big.graph.indices)
    np.testing.assert_array_equal(res.graph.ew, res_big.graph.ew)


def test_ingestor_bad_args():
    with pytest.raises(ValueError):
        StreamIngestor(chunk_edges=0)
    ing = StreamIngestor()
    with pytest.raises(ValueError):
        # No labels, no tokens, no index, but nodes exist.
        ing.add_edge("a", "b")
        ing._labels.clear()
        from repro.store.ingest import IngestStats
        ing.finalize(IngestStats(source="x"))


def test_rmat_edges_full_count_and_deterministic():
    """ISSUE satellite: the self-loop filter used to silently undershoot
    n_edges; slots are now resampled (bounded) to the requested count."""
    for n_nodes, n_edges, seed in [(100, 400, 0), (1000, 5000, 3),
                                   (17, 123, 9)]:
        s, d = rmat_edges(n_nodes, n_edges, seed=seed)
        assert len(s) == n_edges and len(d) == n_edges
        assert (s != d).all()
        assert s.max() < n_nodes and d.max() < n_nodes
        s2, d2 = rmat_edges(n_nodes, n_edges, seed=seed)
        np.testing.assert_array_equal(s, s2)
        np.testing.assert_array_equal(d, d2)
    # Degenerate single-node graph: bounded retries, graceful undershoot.
    s, d = rmat_edges(1, 10, seed=0)
    assert len(s) == 0


def test_from_graph_records_true_counts(setup):
    _, _, result, artifact = setup
    assert result.stats.edges_requested == 1800
    assert result.stats.edges_directed == 1800  # rmat no longer undershoots
    # The artifact manifest carries the stats for provenance.
    assert artifact.stats["edges_requested"] == 1800
    assert artifact.stats["edges_directed"] == 1800


def test_artifact_atomic_overwrite(tmp_path, setup):
    _, _, result, _ = setup
    art1 = write_artifact(tmp_path / "a", result.graph, result.index)
    h1 = art1.content_hash
    art2 = write_artifact(tmp_path / "a", result.graph, result.index,
                          overwrite=True)
    assert art2.content_hash == h1  # same content, same identity
    assert not list(tmp_path.glob("*.tmp-*"))  # no temp debris


def test_lazy_index_binary_search(setup, tmp_path):
    """The open-time fix: artifact.index() materializes NO token dict —
    lookups binary-search the mmapped sorted token table (int keys via
    searchsorted, str keys via utf-8 byte comparison), with clean misses
    below/above/between keys and on wrong-type probes."""
    from repro.store import LazyArtifactIndex

    _, _, result, artifact = setup
    loaded = open_artifact(artifact.path).index()
    assert isinstance(loaded, LazyArtifactIndex)
    # Nothing vocabulary-sized was built at open.
    assert loaded._frozen == {}
    vocab = sorted(result.index.vocabulary())
    assert loaded.df(vocab[0]) == result.index.df(vocab[0])
    assert loaded.lookup(min(vocab) - 1).size == 0
    assert loaded.lookup(max(vocab) + 1000).size == 0
    assert loaded.lookup("not-an-int").size == 0

    labels = ["alpha beta", "beta gamma", "zeta alpha"]
    from repro.graph.structure import build_graph
    g = build_graph([0, 1], [1, 2], 3, labels=labels)
    art = write_artifact(tmp_path / "s", g,
                         InvertedIndex.from_labels(labels))
    li = open_artifact(art.path).index()
    assert isinstance(li, LazyArtifactIndex)
    assert li.lookup("aaaa").size == 0     # before the first key
    assert li.lookup("zzzz").size == 0     # past the last key
    assert li.lookup("bet").size == 0      # prefix of a key, not a key
    assert li.lookup(123).size == 0        # wrong type
    np.testing.assert_array_equal(li.lookup("beta"), [0, 1])
    assert sorted(li.vocabulary()) == \
        sorted(InvertedIndex.from_labels(labels).vocabulary())
