"""Batched multi-query DKS serving (beyond-paper feature)."""

import numpy as np

import jax.numpy as jnp

from repro.core import DKSConfig, run_dks, run_dks_batched
from repro.graph.generators import random_weighted_graph


def test_batched_queries_match_singles():
    g = random_weighted_graph(120, 360, seed=3)
    dg = g.to_device()
    rng = np.random.default_rng(1)
    q = 4
    masks = np.zeros((q, 2, dg.v_pad), bool)
    for i in range(q):
        masks[i, 0, rng.integers(0, 120)] = True
        masks[i, 1, rng.integers(0, 120)] = True
    cfg = DKSConfig(m=2, k=2, max_supersteps=32)
    batched = run_dks_batched(dg, jnp.asarray(masks), cfg)
    for i in range(q):
        single = run_dks(dg, jnp.asarray(masks[i]), cfg)
        np.testing.assert_allclose(np.asarray(single.topk_w),
                                   np.asarray(batched.topk_w[i]))


def test_batched_counters_freeze_after_exit():
    """The vmapped while-loop steps every query until the whole batch
    finishes; finished queries must not keep accumulating msgs/steps
    (freeze_finished).  Mixing a trivially-fast query with slow ones makes
    the unfrozen inflation visible."""
    g = random_weighted_graph(120, 360, seed=5)
    dg = g.to_device()
    masks = np.zeros((3, 2, dg.v_pad), bool)
    # q0: both keywords on one node -> exits immediately.
    masks[0, 0, 7] = masks[0, 1, 7] = True
    # q1/q2: far-apart keyword pairs -> many supersteps.
    masks[1, 0, 0] = masks[1, 1, 100] = True
    masks[2, 0, 3] = masks[2, 1, 110] = True
    cfg = DKSConfig(m=2, k=1, max_supersteps=32)
    batched = run_dks_batched(dg, jnp.asarray(masks), cfg)
    steps = np.asarray(batched.step)
    assert steps.max() > steps.min(), "need heterogeneous convergence"
    for i in range(3):
        single = run_dks(dg, jnp.asarray(masks[i]), cfg)
        assert int(batched.step[i]) == int(single.step)
        assert float(batched.msgs_bfs[i]) == float(single.msgs_bfs)
        assert float(batched.msgs_deep[i]) == float(single.msgs_deep)
        assert bool(batched.budget_hit[i]) == bool(single.budget_hit)
