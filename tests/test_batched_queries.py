"""Batched multi-query DKS serving (beyond-paper feature)."""

import numpy as np

import jax.numpy as jnp

from repro.core import DKSConfig, run_dks, run_dks_batched
from repro.graph.generators import random_weighted_graph


def test_batched_queries_match_singles():
    g = random_weighted_graph(120, 360, seed=3)
    dg = g.to_device()
    rng = np.random.default_rng(1)
    q = 4
    masks = np.zeros((q, 2, dg.v_pad), bool)
    for i in range(q):
        masks[i, 0, rng.integers(0, 120)] = True
        masks[i, 1, rng.integers(0, 120)] = True
    cfg = DKSConfig(m=2, k=2, max_supersteps=32)
    batched = run_dks_batched(dg, jnp.asarray(masks), cfg)
    for i in range(q):
        single = run_dks(dg, jnp.asarray(masks[i]), cfg)
        np.testing.assert_allclose(np.asarray(single.topk_w),
                                   np.asarray(batched.topk_w[i]))
