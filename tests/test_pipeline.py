"""Pipeline parallelism: GPipe over the "pod" axis equals the plain
forward, and the pipelined train step reduces loss (8 forced devices)."""

from tests.test_distributed import run_in_subprocess


def test_pp_forward_matches_plain():
    out = run_in_subprocess("""
        import dataclasses as dc
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import pipeline as pp
        from repro.models import transformer as tfm
        from repro.launch.mesh import sharding_tree

        from repro.shardmap import make_mesh, mesh_scope
        cfg = get_arch("chatglm3-6b").config.smoke()
        cfg = dc.replace(cfg, n_layers=4, d_model=64, n_heads=4,
                         n_kv_heads=2, vocab=128)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        b = tfm.build(cfg, tp=2)
        with mesh_scope(mesh):
            params = tfm.init_params(jax.random.PRNGKey(0), b)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)

            # tfm.forward applies the final norm; pp_hidden_forward does
            # too — compare directly.
            plain_h, _, _ = tfm.forward(params, toks, b, attn_impl="naive")

            piped = jax.jit(lambda p, t: pp.pp_hidden_forward(
                p, t, b, n_stages=2, n_micro=4, attn_impl="naive"))(
                params, toks)
        err = float(jnp.max(jnp.abs(
            piped.astype(jnp.float32) - plain_h.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(plain_h.astype(jnp.float32))))
        out = {"err": err, "scale": scale}
    """)
    assert out["err"] <= 0.05 * max(out["scale"], 1.0), out


def test_pp_train_step_improves_loss():
    out = run_in_subprocess("""
        import dataclasses as dc
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models import lm as lm_lib
        from repro.models import pipeline as pp
        from repro.models import transformer as tfm
        from repro.optim import AdamWConfig

        from repro.shardmap import make_mesh, mesh_scope
        cfg = get_arch("qwen1.5-4b").config.smoke()
        cfg = dc.replace(cfg, n_layers=4, d_model=64, n_heads=4,
                         n_kv_heads=4, vocab=128)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        b = tfm.build(cfg, tp=2)
        with mesh_scope(mesh):
            state = lm_lib.init_train_state(jax.random.PRNGKey(0), b)
            step = jax.jit(pp.make_pp_train_step(
                b, AdamWConfig(lr=3e-3), n_stages=2, n_micro=4,
                attn_impl="naive"), donate_argnums=0)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
            batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
            losses = []
            for _ in range(6):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        out = {"losses": losses}
    """)
    ls = out["losses"]
    assert all(np.isfinite(l) for l in ls), ls
    assert ls[-1] < ls[0], ls


import numpy as np  # noqa: E402
