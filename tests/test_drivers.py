"""End-to-end driver tests (deliverable b exercised under pytest):
training improves loss + checkpoint/restart resumes; serving decodes;
the DKS query CLI answers a query."""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")
ROOT = Path(__file__).resolve().parent.parent


def run_cli(args, timeout=600):
    res = subprocess.run(
        [sys.executable, *args], capture_output=True, text=True,
        timeout=timeout, cwd=ROOT,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"})
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_train_driver_improves_and_resumes(tmp_path):
    class Args:
        arch = "granite-moe-3b-a800m"
        steps = 14
        batch = 4
        seq = 32
        lr = 3e-3
        grad_accum = 1
        seed = 0
        smoke = True
        ckpt_dir = str(tmp_path)
        ckpt_every = 5
        log_every = 10

    from repro.launch.train import train_lm
    out1 = train_lm(Args())
    assert out1["last_loss"] < out1["first_loss"]

    # Restart: resumes from step 10 checkpoint and continues to 20.
    a2 = Args()
    a2.steps = 20
    out2 = train_lm(a2)
    assert np.isfinite(out2["last_loss"])


def test_serve_driver_cli():
    out = run_cli(["-m", "repro.launch.serve", "--arch", "chatglm3-6b",
                   "--smoke", "--batch", "2", "--prompt-len", "8",
                   "--gen", "4"])
    assert "decode:" in out and "tok/s" in out


def test_dks_query_cli():
    out = run_cli(["-m", "repro.launch.dks_query",
                   "--dataset", "sec-rdfabout-cpu", "--m", "2", "--k", "1",
                   "--max-supersteps", "12"])
    assert "DKS finished" in out
    assert "top answers" in out


def test_ingest_cli_smoke():
    """The store acceptance run: generate -> stream-ingest -> write
    artifact (atomic) -> checksum-verified mmap reopen -> bit-identical
    query parity vs the in-memory build — asserted by the CLI itself."""
    out = run_cli(["-m", "repro.launch.ingest", "--smoke"])
    assert "reopened with mmap" in out
    assert "bit-identical" in out
    assert "ingest smoke invariants hold" in out


def test_ingest_then_query_artifact(tmp_path):
    """An artifact written by the ingest CLI serves the query CLI."""
    art = tmp_path / "artifact"
    run_cli(["-m", "repro.launch.ingest", "--smoke", "--out", str(art)])
    out = run_cli(["-m", "repro.launch.dks_query", "--artifact", str(art),
                   "--m", "2", "--k", "1", "--max-supersteps", "12"])
    assert "DKS finished" in out
    assert "top answers" in out


def test_serve_dks_cli_smoke():
    """The serving acceptance run: >= 8 concurrent clients, batch
    coalescing (mean fill > 1), warm cache hits, and parity with the
    direct engine — the CLI asserts all of it under --smoke."""
    out = run_cli(["-m", "repro.launch.serve_dks", "--smoke"])
    assert "batch-fill" in out and "cache" in out
    assert "verified:" in out
    assert "smoke invariants hold" in out


def test_dks_query_cli_pallas_parity():
    """The CI interpret-mode smoke as a tier-1 test: one query through
    the fused pallas kernel with --parity building the jnp twin and
    asserting bit-identical weights + superstep count."""
    out = run_cli(["-m", "repro.launch.dks_query",
                   "--dataset", "sec-rdfabout-cpu", "--backend", "pallas",
                   "--parity", "--m", "2", "--k", "1",
                   "--max-supersteps", "12"])
    assert "parity: pallas == jnp bit-identical" in out
