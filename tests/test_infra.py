"""Infrastructure tests: checkpointing (crash-safe commit, elastic restore),
fault/straggler handling, data pipeline determinism, partitioner, index,
sampler."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore_tree, save_tree
from repro.data import PrefetchIterator, lm_synthetic_stream, recsys_synthetic_stream
from repro.distributed.fault import StepGuard, StragglerPolicy
from repro.graph.generators import lod_like_graph, random_weighted_graph
from repro.graph.index import InvertedIndex
from repro.graph.partition import apply_partition, edge_cut, hash_partition
from repro.graph.sampler import plan_sizes, sample_subgraph


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = tree()
    save_tree(t, tmp_path, step=3)
    assert latest_step(tmp_path) == 3
    out = restore_tree(t, tmp_path, 3)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_torn_write_ignored(tmp_path):
    t = tree()
    save_tree(t, tmp_path, step=1)
    # Simulate a crash mid-save: directory without _COMMITTED.
    bad = tmp_path / "step_2"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 1


def test_checkpoint_async_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=True)
    t = tree()
    for s in (1, 2, 3, 4):
        ck.save(t, s)
    ck.wait()
    assert ck.latest() == 4
    steps = sorted(int(d.name.split("_")[1]) for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_restore_with_sharding(tmp_path):
    """Elastic restore: device_put onto explicit shardings (1-device mesh
    here; the same path reshapes onto any mesh)."""
    from repro.shardmap import make_mesh
    mesh = make_mesh((1,), ("data",))
    t = tree()
    save_tree(t, tmp_path, step=1)
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), t)
    out = restore_tree(t, tmp_path, 1, shardings=sh)
    assert out["a"].sharding.mesh.shape == {"data": 1}


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    t = tree()
    save_tree(t, tmp_path, step=1)
    bad = {**t, "a": jnp.zeros((4, 4))}
    with pytest.raises(ValueError):
        restore_tree(bad, tmp_path, 1)


def test_step_guard_retries_transient_failure():
    calls = {"n": 0}

    def flaky_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("simulated preemption")
        return state + batch, {"loss": jnp.float32(1.0)}

    guard = StepGuard(max_retries=2)
    new_state, aux, info = guard.run(flaky_step, jnp.float32(1.0),
                                     jnp.float32(2.0))
    assert float(new_state) == 3.0
    assert info["retries"] == 1
    assert ("retry", "RuntimeError('simulated preemption')") in guard.events


def test_step_guard_gives_up():
    def dead_step(state, batch):
        raise RuntimeError("hard fault")

    guard = StepGuard(max_retries=1)
    with pytest.raises(RuntimeError):
        guard.run(dead_step, jnp.float32(0.0), jnp.float32(0.0))


def test_straggler_policy_flags_slow_steps():
    p = StragglerPolicy(threshold=2.0, patience=2)
    assert not p.observe(1.0)
    assert not p.observe(1.1)
    assert p.observe(5.0)
    assert not p.should_escalate
    assert p.observe(5.0)
    assert p.should_escalate


def test_lm_stream_deterministic_and_resumable():
    a = list(zip(range(3), lm_synthetic_stream(100, 2, 8, seed=1)))
    b = list(zip(range(3), lm_synthetic_stream(100, 2, 8, seed=1)))
    for (_, x), (_, y) in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # skip resumes mid-stream
    c = next(lm_synthetic_stream(100, 2, 8, seed=1, skip=2))
    np.testing.assert_array_equal(a[2][1]["tokens"], c["tokens"])


def test_streams_shard_disjoint():
    x = next(lm_synthetic_stream(1000, 4, 16, seed=3, shard_id=0, n_shards=2))
    y = next(lm_synthetic_stream(1000, 4, 16, seed=3, shard_id=1, n_shards=2))
    assert not np.array_equal(x["tokens"], y["tokens"])


def test_prefetch_iterator():
    it = PrefetchIterator(iter(range(5)), depth=2)
    assert list(it) == [0, 1, 2, 3, 4]


def test_prefetch_propagates_errors():
    def gen():
        yield 1
        raise ValueError("boom")

    it = PrefetchIterator(gen())
    assert next(it) == 1
    with pytest.raises(ValueError):
        for _ in it:
            pass


def test_hash_partition_and_edge_cut():
    g = random_weighted_graph(100, 300, seed=0)
    part = hash_partition(100, 4, seed=0)
    cut = edge_cut(g, part)
    assert 0.5 < cut < 1.0  # random partition of a random graph: ~3/4
    g2 = apply_partition(g, part)
    assert g2.n_nodes == g.n_nodes
    assert g2.n_edges_sym == g.n_edges_sym


def test_inverted_index():
    g, tokens = lod_like_graph(200, 400, seed=0, vocab=50)
    idx = InvertedIndex.from_token_matrix(tokens)
    tok = idx.vocabulary()[0]
    nodes = idx.lookup(tok)
    assert len(nodes) == idx.df(tok) > 0
    for n in nodes:
        assert tok in tokens[n]
    masks = idx.keyword_masks([tok], 200)
    assert masks.sum() == len(nodes)


def test_index_from_labels():
    idx = InvertedIndex.from_labels(["alpha beta", "beta gamma", "alpha"])
    np.testing.assert_array_equal(idx.lookup("alpha"), [0, 2])
    np.testing.assert_array_equal(idx.lookup("beta"), [0, 1])
    assert idx.df("nope") == 0


def test_sampler_shapes_and_validity():
    g = random_weighted_graph(500, 2000, seed=1)
    seeds = np.arange(16, dtype=np.int32)
    sub = sample_subgraph(g, seeds, fanout=[3, 2], seed=0)
    n_pad, e_pad = plan_sizes(16, [3, 2])
    assert sub.node_ids.shape == (n_pad,)
    assert sub.edge_src.shape == (e_pad,)
    # Every valid edge endpoint is a valid node slot.
    ev = np.asarray(sub.edge_valid)
    assert np.all(np.asarray(sub.node_valid)[np.asarray(sub.edge_src)[ev]])
    # Sampled edges are real graph edges.
    for s_loc, d_loc in zip(np.asarray(sub.edge_src)[ev][:20],
                            np.asarray(sub.edge_dst)[ev][:20]):
        u = int(sub.node_ids[s_loc])
        v = int(sub.node_ids[d_loc])
        nbrs, _ = g.neighbors(v)
        assert u in nbrs
