"""Property-based tests (hypothesis) for the system's invariants: the
top-K min-plus lattice, the SPA bounds, and the HLO analyzer."""

import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import INF
from repro.core import semiring
from repro.core.spa import nu_lower_bound, spa_cover_dp, split_pairs

ks = st.integers(1, 4)
vals = st.lists(st.integers(1, 30), min_size=1, max_size=12)


def to_vec(xs, k):
    v = jnp.asarray(sorted(set(xs))[:k] + [INF] * k, jnp.float32)[:k]
    return v


@settings(max_examples=40, deadline=None)
@given(a=vals, b=vals, k=ks)
def test_topk_merge_commutative_associative_idempotent(a, b, k):
    va, vb = to_vec(a, k), to_vec(b, k)
    ab = semiring.topk_merge(va, vb)
    ba = semiring.topk_merge(vb, va)
    np.testing.assert_array_equal(np.asarray(ab), np.asarray(ba))
    # Idempotent: merging a vector with itself is a no-op.
    np.testing.assert_array_equal(
        np.asarray(semiring.topk_merge(va, va)), np.asarray(va))
    # Merge result equals brute force top-k distinct.
    brute = sorted(set([float(x) for x in list(va) + list(vb) if x < INF]))
    brute = (brute + [INF] * k)[:k]
    np.testing.assert_allclose(np.asarray(ab), brute)


@settings(max_examples=40, deadline=None)
@given(a=vals, b=vals, k=ks)
def test_outer_combine_matches_bruteforce(a, b, k):
    va, vb = to_vec(a, k), to_vec(b, k)
    got = semiring.outer_combine(va, vb)
    sums = sorted({float(x) + float(y) for x in va for y in vb
                   if x < INF and y < INF})
    want = (sums + [INF] * k)[:k]
    np.testing.assert_allclose(np.asarray(got), np.minimum(want, INF),
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(5, 40), v=st.integers(2, 10), k=ks,
       seed=st.integers(0, 99))
def test_segment_topk_matches_numpy(n, v, k, seed):
    rng = np.random.default_rng(seed)
    vals_ = rng.integers(1, 50, n).astype(np.float32)
    seg = rng.integers(0, v, n).astype(np.int32)
    got = np.asarray(semiring.segment_topk_min(
        jnp.asarray(vals_), jnp.asarray(seg), v, k))
    for s in range(v):
        mine = sorted(set(vals_[seg == s]))[:k]
        mine = mine + [INF] * (k - len(mine))
        np.testing.assert_allclose(got[s], mine)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 5), seed=st.integers(0, 99))
def test_nu_lower_bound_sound_vs_cover(m, seed):
    """nu[full] is a valid lower bound: it never exceeds any achievable
    combination of g-values + one arrival step."""
    rng = np.random.default_rng(seed)
    g = rng.integers(1, 20, 1 << m).astype(np.float32)
    g[0] = INF
    # Randomly mark some sets unseen.
    g[rng.random(1 << m) < 0.3] = INF
    e_min = 1.0
    nu = np.asarray(nu_lower_bound(jnp.asarray(g), jnp.float32(e_min), m))
    full = (1 << m) - 1
    # Direct arrival bound.
    assert nu[full] <= g[full] + e_min + 1e-5
    # Any split with one arrival must dominate nu.
    for t, a, b in split_pairs(m):
        if t == full and g[a] < INF and g[b] < INF:
            assert nu[full] <= min(g[a] + e_min + g[b],
                                   g[a] + g[b] + e_min) + 1e-4


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 4), seed=st.integers(0, 99))
def test_spa_cover_dp_is_min_cover(m, seed):
    """On monotone path-length estimates (real DKS tables are monotone in
    set inclusion), the cover DP equals the brute-force minimum cover."""
    import itertools

    rng = np.random.default_rng(seed)
    shat = rng.integers(1, 30, 1 << m).astype(np.float64)
    shat[0] = 0.0
    # Monotonize: superset >= any subset (path-length property).
    full = (1 << m) - 1
    for t in sorted(range(1, full + 1), key=lambda x: bin(x).count("1")):
        a = (t - 1) & t
        while a:
            shat[t] = max(shat[t], shat[a])
            a = (a - 1) & t
    shat[0] = INF
    got = float(spa_cover_dp(jnp.asarray(shat, jnp.float32), m))
    best = INF
    sets = list(range(1, full + 1))
    for r in range(1, m + 1):
        for combo in itertools.combinations(sets, r):
            u = 0
            for c in combo:
                u |= c
            if u == full:
                best = min(best, float(sum(shat[c] for c in combo)))
    assert got == pytest.approx(best, abs=1e-3)


def test_hlo_analyzer_counts_loop_multipliers():
    import jax
    from repro.analysis import analyze_hlo

    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y.sum()

    c = jax.jit(f).lower(jnp.ones((64, 64)), jnp.ones((64, 64))).compile()
    s = analyze_hlo(c.as_text())
    # 12 iterations x 2*64^3 flops
    assert abs(s.dot_flops - 12 * 2 * 64**3) / (12 * 2 * 64**3) < 0.01
    assert s.static_loops == 1 and s.dynamic_loops == 0


def test_hlo_analyzer_dynamic_loop_flagged():
    import jax
    from repro.analysis import analyze_hlo

    def f(x):
        def cond(c):
            return c[0].sum() > 0
        def body(c):
            return (c[0] - 0.1, c[1] @ c[1])
        return jax.lax.while_loop(cond, body, (x, x))[1]

    c = jax.jit(f).lower(jnp.ones((8, 8))).compile()
    s = analyze_hlo(c.as_text())
    assert s.dynamic_loops >= 1
