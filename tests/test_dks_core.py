"""Core DKS engine vs. exact oracles (paper Theorem 1 / Def. 2.2)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import INF
from repro.core import (
    DKSConfig, run_dks, extract_answers, dreyfus_wagner, brute_force_topk,
)
from repro.core import dks as dks_mod
from repro.graph.generators import grid_graph, random_weighted_graph
from repro.graph.structure import build_graph


def make_masks(groups, n_nodes):
    m = np.zeros((len(groups), n_nodes), bool)
    for i, grp in enumerate(groups):
        m[i, list(grp)] = True
    return m


def run_engine(g, groups, k=1, **kw):
    masks = make_masks(groups, g.n_nodes)
    dg = g.to_device()
    cfg = DKSConfig(m=len(groups), k=k, **kw)
    state = run_dks(dg, jnp.asarray(masks), cfg)
    return state, cfg, masks


def test_single_edge():
    #  0 --1-- 1 ; query {0}, {1}
    g = build_graph([0], [1], 2, w=np.asarray([1.0], np.float32))
    state, cfg, _ = run_engine(g, [[0], [1]])
    assert float(state.topk_w[0]) == 1.0


def test_path_graph_root_in_middle():
    # 0-1-2-3-4 unit weights, keywords at ends -> optimum 4.
    g = build_graph([0, 1, 2, 3], [1, 2, 3, 4], 5,
                    w=np.ones(4, np.float32))
    state, _, _ = run_engine(g, [[0], [4]])
    assert float(state.topk_w[0]) == 4.0


def test_star_answer_tree():
    # Paper Fig. 1 style: center 0, leaves 1,2,3 with weights 1,2,3.
    g = build_graph([0, 0, 0], [1, 2, 3], 4,
                    w=np.asarray([1, 2, 3], np.float32))
    state, cfg, masks = run_engine(g, [[1], [2], [3]])
    assert float(state.topk_w[0]) == 6.0
    answers = extract_answers(np.asarray(state.S), g, masks, k=1)
    assert answers[0].weight == 6.0
    assert answers[0].root == 0 or len(answers[0].edges) == 3


def test_unbalanced_tree_needs_deep_messages():
    # Paper Fig. 4(a): BFS alone only finds root-balanced trees.  Chain
    # q1 -1- a -1- b -1- q2 with q2 also 10 away from q1 directly.
    # Optimal tree is the chain (weight 3), whose best root is unbalanced.
    g = build_graph([0, 1, 2, 0], [1, 2, 3, 3], 4,
                    w=np.asarray([1, 1, 1, 10], np.float32))
    state, _, _ = run_engine(g, [[0], [3]])
    assert float(state.topk_w[0]) == 3.0


def test_multi_keyword_node():
    # One node contains both keywords -> weight 0.
    g = build_graph([0], [1], 2, w=np.asarray([1.0], np.float32))
    groups = [[0], [0]]
    state, _, _ = run_engine(g, groups)
    assert float(state.topk_w[0]) == 0.0


def test_infeasible_query_terminates():
    # Keyword 1 exists nowhere.
    g = build_graph([0], [1], 2, w=np.asarray([1.0], np.float32))
    state, _, _ = run_engine(g, [[0], []])
    assert float(state.topk_w[0]) >= INF
    assert bool(state.done)


@pytest.mark.parametrize("seed", range(6))
def test_random_graphs_match_dreyfus_wagner(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 14))
    g = random_weighted_graph(n, n + int(rng.integers(0, 8)), seed=seed)
    m = int(rng.integers(2, 4))
    groups = [rng.choice(n, size=int(rng.integers(1, 3)), replace=False)
              for _ in range(m)]
    opt = dreyfus_wagner(g, groups)
    state, _, _ = run_engine(g, groups, max_supersteps=64)
    got = float(state.topk_w[0])
    assert got == pytest.approx(opt, abs=1e-3), f"engine {got} vs DW {opt}"


@pytest.mark.parametrize("seed", range(4))
def test_topk_answers_match_brute_force(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(5, 8))
    g = random_weighted_graph(n, n + 2, seed=seed, max_w=4)
    groups = [[int(rng.integers(0, n))] for _ in range(2)]
    k = 3
    # Full list of achievable minimal-tree weights (large K).
    all_weights = [w for w in brute_force_topk(g, groups, 50) if w < INF]
    state, cfg, masks = run_engine(g, groups, k=k, max_supersteps=64)
    answers = extract_answers(np.asarray(state.S), g, masks, k=k)
    got = sorted({a.weight for a in answers})
    # Engine answers must (a) include the optimum, (b) be true tree weights.
    assert got[0] == pytest.approx(all_weights[0], abs=1e-3)
    for w in got:
        assert any(abs(w - e) < 1e-3 for e in all_weights), (
            f"weight {w} is not an achievable minimal-tree weight {all_weights}")
    # Every returned answer's true weight never exceeds its DP value.
    for a in answers:
        assert a.weight <= a.raw_value + 1e-3


def test_early_exit_never_misses_optimum():
    # exit_mode="sound" must match a run with no early exit.
    for seed in range(5):
        g = random_weighted_graph(12, 20, seed=seed)
        rng = np.random.default_rng(seed)
        groups = [[int(rng.integers(0, 12))] for _ in range(3)]
        s_exit, _, _ = run_engine(g, groups, k=2, exit_mode="sound")
        s_full, _, _ = run_engine(g, groups, k=2, exit_mode="none",
                                  max_supersteps=128)
        np.testing.assert_allclose(
            np.asarray(s_exit.topk_w), np.asarray(s_full.topk_w), atol=1e-3)
        # And the early exit actually exits earlier or at the same step.
        assert int(s_exit.step) <= int(s_full.step)


def test_grid_graph_exact():
    g = grid_graph(4, 4)
    groups = [[0], [15], [3]]
    opt = dreyfus_wagner(g, groups)
    state, _, _ = run_engine(g, groups)
    assert float(state.topk_w[0]) == pytest.approx(opt)


def test_message_budget_forces_stop():
    g = grid_graph(6, 6)
    groups = [[0], [35]]
    state, _, _ = run_engine(g, groups, message_budget=10.0)
    assert bool(state.done)
    assert bool(state.budget_hit)


def test_explored_fraction_less_than_full():
    # Early exit should leave part of the graph unexplored (paper Fig. 13).
    g = grid_graph(12, 12)
    groups = [[0], [1]]
    state, _, _ = run_engine(g, groups, exit_mode="sound", max_supersteps=64)
    explored = float(jnp.mean(state.visited[: g.n_nodes]))
    assert explored < 0.9
