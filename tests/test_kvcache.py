"""int8 KV cache: quantization round-trip + decode consistency vs bf16."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import kvcache, transformer as tfm

KEY = jax.random.PRNGKey(0)


def test_quantize_roundtrip():
    x = jax.random.normal(KEY, (2, 1, 4, 64), jnp.bfloat16) * 3
    q, s = kvcache.quantize_kv(x)
    deq = q.astype(jnp.float32) * s
    err = np.max(np.abs(deq - np.asarray(x, np.float32)))
    amax = float(jnp.max(jnp.abs(x.astype(jnp.float32))))
    assert err <= amax / 127.0 + 1e-6


def test_decode_attention_quant_matches_full():
    rng = np.random.default_rng(0)
    b_, s, hq, hkv, dh = 2, 64, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(b_, 1, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b_, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b_, s, hkv, dh)), jnp.float32)
    pos = 40
    kq, ks = kvcache.quantize_kv(k)
    vq, vs = kvcache.quantize_kv(v)
    got = kvcache.decode_attention_quant(q, kq, ks, vq, vs,
                                         jnp.int32(pos), chunk=16)
    from repro.kernels.flash_attention.ref import attention_ref
    want = attention_ref(q, k[:, : pos + 1], v[:, : pos + 1], causal=True,
                         q_offset=pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.06, rtol=0.06)


def test_decode_step_quant_consistent_with_bf16():
    """Greedy decode tokens with int8 cache match the bf16-cache decode on
    a reduced model (same argmax, close logits)."""
    cfg = get_arch("chatglm3-6b").config.smoke()
    b = tfm.build(cfg, tp=1)
    params = tfm.init_params(KEY, b)
    bsz, prompt = 2, 8
    toks = jax.random.randint(KEY, (bsz, prompt), 0, cfg.vocab)
    max_seq = 16

    # Warm both caches via repeated single-token decode of the prompt.
    cache = tfm.init_cache(b, bsz, max_seq)
    cache_q = kvcache.init_cache_quant(b, bsz, max_seq)
    logits = logits_q = None
    for t in range(prompt):
        tok = toks[:, t][:, None]
        logits, cache = tfm.decode_step(params, cache, tok, b,
                                        attn_impl="naive")
        logits_q, cache_q = tfm.decode_step_quant(params, cache_q, tok, b,
                                                  chunk=8)
    lf = np.asarray(logits[:, 0, : cfg.vocab], np.float32)
    lq = np.asarray(logits_q[:, 0, : cfg.vocab], np.float32)
    # int8 KV: logits close, greedy tokens identical.
    np.testing.assert_allclose(lq, lf, atol=0.25, rtol=0.25)
    np.testing.assert_array_equal(lq.argmax(-1), lf.argmax(-1))
