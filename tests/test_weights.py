"""Typed-edge / weight-policy tests: type-aware dedup, the weight floor,
effective-weight semantics, v1 artifact compatibility (bit-identical under
the default policy), policy-distinct cache tokens at the ResultCache
level, predicate-filtered end-to-end queries, distinct top-K answers
under duplicate weights across predicates, and serve shape-key safety."""

import json

import numpy as np
import pytest

from repro import INF
from repro.engine import ExecutionPolicy, QueryEngine, WeightPolicy
from repro.graph import (
    MIN_EDGE_WEIGHT,
    apply_weight_policy,
    build_graph,
    effective_weights,
)
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex
from repro.serve import ResultCache
from repro.store import from_graph, open_artifact, write_artifact


def typed_diamond():
    """4 nodes, 3 predicates: a direct ``funds`` edge (weight 1) between
    the keyword nodes and two equal-weight (2.0) two-hop paths — one all
    ``knows``, one all ``cites`` — through distinct middles.

        alpha --funds(1)-- beta
        alpha --knows(1)-- mid1 --knows(1)-- beta
        alpha --cites(1)-- mid2 --cites(1)-- beta
    """
    labels = ["alpha", "mid1", "mid2", "beta"]
    src = np.array([0, 0, 1, 0, 2], np.int32)
    dst = np.array([3, 1, 3, 2, 3], np.int32)
    w = np.ones(5, np.float32)
    pred = np.array([0, 1, 1, 2, 2], np.int32)      # funds,knows,knows,cites,cites
    conf = np.array([0.5, 1.0, 1.0, 2.0, 2.0], np.float32)
    g = build_graph(src, dst, 4, w=w, labels=labels, pred=pred, conf=conf,
                    pred_names=["funds", "knows", "cites"])
    return g, InvertedIndex.from_labels(labels)


# ----------------------------------------------------------------------
# build_graph: type-aware dedup + the weight floor
# ----------------------------------------------------------------------


def test_typed_dedup_preserves_parallel_predicate_edges():
    """Two (u, v) edges with distinct predicates must survive as parallel
    CSR entries (the untyped dedup would collapse them to the min)."""
    src = np.array([0, 0], np.int32)
    dst = np.array([1, 1], np.int32)
    w = np.array([2.0, 3.0], np.float32)
    gt = build_graph(src, dst, 2, w=w,
                     pred=np.array([0, 1], np.int32),
                     pred_names=["a", "b"])
    nbrs, ws = gt.neighbors(0)
    assert list(nbrs) == [1, 1]
    assert sorted(ws) == [2.0, 3.0]
    # edge_channel resolves the cheapest parallel entry — the one
    # _edge_weight (and so backtrace / rendering) uses.
    assert gt.edge_channel(0, 1) == ("a", 1.0)

    gu = build_graph(src, dst, 2, w=w)
    nbrs_u, ws_u = gu.neighbors(0)
    assert list(nbrs_u) == [1] and list(ws_u) == [2.0]
    assert gu.edge_channel(0, 1) is None


def test_typed_dedup_same_predicate_keeps_min_weight_max_conf():
    src = np.array([0, 0, 0], np.int32)
    dst = np.array([1, 1, 1], np.int32)
    w = np.array([3.0, 2.0, 2.0], np.float32)
    conf = np.array([0.9, 0.2, 0.7], np.float32)
    gt = build_graph(src, dst, 2, w=w,
                     pred=np.zeros(3, np.int32), conf=conf,
                     pred_names=["p"])
    nbrs, ws = gt.neighbors(0)
    assert list(nbrs) == [1] and list(ws) == [2.0]
    assert gt.edge_channel(0, 1) == ("p", pytest.approx(0.7))


def test_weight_floor_clamps_instead_of_raising():
    """Weights rounding to 0 (confidence-scaled provenance) clamp up to
    the documented MIN_EDGE_WEIGHT floor; negative weights still raise."""
    src = np.array([0], np.int32)
    dst = np.array([1], np.int32)
    g = build_graph(src, dst, 2, w=np.array([0.0], np.float32))
    assert g.ew.min() == np.float32(MIN_EDGE_WEIGHT)
    with pytest.raises(ValueError, match="non-negative"):
        build_graph(src, dst, 2, w=np.array([-1.0], np.float32))
    with pytest.raises(ValueError, match="positive"):
        build_graph(src, dst, 2, w=np.array([1.0], np.float32),
                    pred=np.zeros(1, np.int32),
                    conf=np.array([0.0], np.float32), pred_names=["p"])


# ----------------------------------------------------------------------
# WeightPolicy + effective_weights semantics
# ----------------------------------------------------------------------


def test_weight_policy_validation():
    assert WeightPolicy().is_default
    assert not WeightPolicy(kind="confidence").is_default
    assert not WeightPolicy(predicates=("a",)).is_default
    with pytest.raises(ValueError, match="kind"):
        WeightPolicy(kind="karma")
    with pytest.raises(ValueError, match="blend"):
        WeightPolicy(kind="confidence", blend=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        WeightPolicy(predicates=())


def test_effective_weights_semantics():
    w = np.array([2.0, 3.0, INF, 1.0], np.float32)
    pred = np.array([0, 1, 0, 1], np.int32)
    conf = np.array([2.0, 0.5, 4.0, 1e9], np.float32)
    names = {"a": 0, "b": 1}
    # confidence: w / conf**blend; INF stays INF; huge conf hits the floor.
    eff = effective_weights(w, pred, conf,
                            WeightPolicy(kind="confidence", blend=1.0),
                            names)
    np.testing.assert_allclose(
        eff, [1.0, 6.0, INF, MIN_EDGE_WEIGHT], rtol=1e-6)
    # blend=2 bites harder.
    eff2 = effective_weights(w, pred, conf,
                             WeightPolicy(kind="confidence", blend=2.0),
                             names)
    assert eff2[0] == pytest.approx(0.5) and eff2[1] == pytest.approx(12.0)
    # predicate filter: disallowed -> INF (disconnected), allowed kept.
    filt = effective_weights(w, pred, conf,
                             WeightPolicy(predicates=("b",)), names)
    np.testing.assert_allclose(filt, [INF, 3.0, INF, 1.0])
    # unknown names are a typo, not a silent no-match filter.
    with pytest.raises(ValueError, match="unknown predicate"):
        effective_weights(w, pred, conf,
                          WeightPolicy(predicates=("nope",)), names)


def test_apply_weight_policy_requires_typed_graph():
    g, _ = lod_like_graph(60, 180, seed=3, vocab=20)
    assert apply_weight_policy(g, WeightPolicy()) is g
    assert apply_weight_policy(g, None) is g
    with pytest.raises(ValueError, match="typed"):
        apply_weight_policy(g, WeightPolicy(kind="confidence"))


# ----------------------------------------------------------------------
# v1 artifact compatibility: bit-identical under the default policy
# ----------------------------------------------------------------------


def test_v1_artifact_opens_and_serves_bit_identically(tmp_path):
    """An untyped artifact whose manifest says format v1 (the pre-typed
    layout: same buffers, no typed channel) still opens, and its engine
    serves bit-identical results to the in-memory build under the
    default WeightPolicy."""
    g, tokens = lod_like_graph(400, 1200, seed=5, vocab=80)
    result = from_graph(g, tokens=tokens)
    art = write_artifact(tmp_path / "a", result.graph, result.index)
    assert art.format_version == 2 and not art.typed
    manifest = json.loads((art.path / "manifest.json").read_text())
    manifest["format_version"] = 1
    (art.path / "manifest.json").write_text(json.dumps(manifest))

    reopened = open_artifact(art.path)
    assert reopened.format_version == 1
    assert not reopened.typed and reopened.predicates == []
    e_mem = QueryEngine.build(g, index=result.index)
    e_art = QueryEngine.build(artifact=reopened)
    toks = sorted(result.index.vocabulary(), key=result.index.df)
    q = [t for t in toks if 2 <= result.index.df(t) <= 40][:3]
    r_mem = e_mem.query(q, k=2, extract=False)
    r_art = e_art.query(q, k=2, extract=False)
    np.testing.assert_array_equal(r_mem.weights, r_art.weights)
    assert r_mem.supersteps == r_art.supersteps
    # Non-default policies need the typed channel a v1 artifact lacks.
    with pytest.raises(ValueError, match="typed"):
        QueryEngine.build(
            artifact=reopened,
            policy=ExecutionPolicy(
                weights=WeightPolicy(kind="confidence")))


# ----------------------------------------------------------------------
# Cache / serving safety across policies
# ----------------------------------------------------------------------


def test_result_cache_misses_across_weight_policies(tmp_path):
    """ISSUE acceptance: two engines over the SAME artifact under two
    weight policies get distinct cache_tokens — at the ResultCache level,
    one policy's answers can never be served to the other."""
    g, index = typed_diamond()
    art = write_artifact(tmp_path / "typed", g, index)
    assert art.typed and art.predicates == ["funds", "knows", "cites"]

    e_deg = QueryEngine.build(artifact=open_artifact(art.path))
    e_conf = QueryEngine.build(
        artifact=open_artifact(art.path),
        policy=ExecutionPolicy(weights=WeightPolicy(kind="confidence")))
    e_conf2 = QueryEngine.build(
        artifact=open_artifact(art.path),
        policy=ExecutionPolicy(weights=WeightPolicy(kind="confidence")))
    q = ["alpha", "beta"]
    assert e_deg.version == e_conf.version  # same artifact content hash

    cache = ResultCache(capacity=8)
    cache.put(e_deg.cache_token(q, 1), "degree-ranked answer")
    assert cache.get(e_conf.cache_token(q, 1)) is None
    # Same policy, fresh build (serve restart): the token is stable.
    cache.put(e_conf.cache_token(q, 1), "confidence-ranked answer")
    assert cache.get(e_conf2.cache_token(q, 1)) == "confidence-ranked answer"
    assert cache.get(e_deg.cache_token(q, 1)) == "degree-ranked answer"


def test_shape_key_differs_across_weight_policies():
    """The batcher must never co-batch requests admitted under engines
    with different weight policies, even at identical (m, k, version)."""
    from concurrent.futures import Future

    from repro.serve.batcher import Request

    g, index = typed_diamond()
    e_deg = QueryEngine.build(g, index=index)
    e_filt = QueryEngine.build(
        g, index=index,
        policy=ExecutionPolicy(weights=WeightPolicy(predicates=("knows",))))

    def req(engine):
        return Request(keywords=("alpha", "beta"), k=1, overrides=(),
                       future=Future(), t_submit=0.0, engine=engine)

    assert req(e_deg).shape_key != req(e_filt).shape_key
    assert req(e_deg).shape_key == req(e_deg).shape_key


def test_per_call_weights_override_rejected():
    g, index = typed_diamond()
    engine = QueryEngine.build(g, index=index)
    with pytest.raises(ValueError, match="weights"):
        engine.query(["alpha", "beta"], k=1,
                     weights=WeightPolicy(kind="confidence"))
    with pytest.raises(ValueError, match="weights"):
        engine.cache_token(["alpha", "beta"], 1,
                           weights=WeightPolicy(kind="confidence"))


# ----------------------------------------------------------------------
# End-to-end ranking semantics
# ----------------------------------------------------------------------


def test_distinct_topk_under_duplicate_weights_across_predicates():
    """Satellite acceptance: heterogeneous per-edge provenance produces
    parallel equal-weight explanations (the knows path and the cites path
    both weigh 2.0) — top-K must return them as DISTINCT answer trees,
    not merge them on the duplicate weight."""
    g, index = typed_diamond()
    engine = QueryEngine.build(g, index=index)
    res = engine.query(["alpha", "beta"], k=3)
    assert len(res.answers) == 3
    assert sorted(a.weight for a in res.answers) == [1.0, 2.0, 2.0]
    node_sets = [frozenset(a.nodes) for a in res.answers]
    assert len(set(node_sets)) == 3, "equal-weight trees merged"
    assert {1, 2} <= set().union(*node_sets), \
        "one of the parallel predicate paths was dropped"


def test_predicate_filter_end_to_end():
    """A predicate-filtered engine returns only trees whose rendered
    edges carry allowed predicates — and its best answer differs from
    the unfiltered engine's (which rides the direct funds edge)."""
    from repro.answers import render_tree

    g, index = typed_diamond()
    e_all = QueryEngine.build(g, index=index)
    e_knows = QueryEngine.build(
        g, index=index,
        policy=ExecutionPolicy(weights=WeightPolicy(predicates=("knows",))))

    r_all = e_all.query(["alpha", "beta"], k=1)
    assert r_all.best_weight == 1.0  # the direct funds edge
    r_knows = e_knows.query(["alpha", "beta"], k=2)
    assert r_knows.best_weight == 2.0  # forced through mid1
    assert r_knows.answers
    for a in r_knows.answers:
        rt = render_tree(a, graph=e_knows.graph)
        assert rt.edges, "filtered answer lost its edges"
        for e in rt.edges:
            assert e.predicate == "knows", rt.describe()
    # The rendered description carries the provenance tag.
    rt = render_tree(r_knows.answers[0], graph=e_knows.graph)
    assert "[knows]" in rt.describe()


def test_confidence_policy_reranks():
    """Under confidence blending the cites path (conf 2.0 -> effective
    weight 1.0 total) must beat the funds edge (conf 0.5 -> 2.0)."""
    g, index = typed_diamond()
    e_conf = QueryEngine.build(
        g, index=index,
        policy=ExecutionPolicy(
            weights=WeightPolicy(kind="confidence", blend=1.0)))
    res = e_conf.query(["alpha", "beta"], k=1)
    assert res.best_weight == pytest.approx(1.0)
    tree = res.answers[0]
    assert 2 in tree.nodes, "confidence ranking did not pick the cites path"
