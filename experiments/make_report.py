"""Render the §Dry-run and §Roofline markdown tables from the dry-run JSONs.

    PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""

import json
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent


def load_dir(d: Path) -> dict:
    out = {}
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        out[r["cell"]] = r
    return out


def roofline_frac(r: dict) -> float:
    rl = r["roofline"]
    bound = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
    ideal = rl["model_flops"] / rl["chips"] / 197e12
    return ideal / bound if bound > 0 else 0.0


def table(cur: dict, base: dict | None, mesh: str) -> str:
    rows = []
    for cell, r in cur.items():
        rl = r["roofline"]
        frac = roofline_frac(r)
        base_frac = roofline_frac(base[cell]) if base and cell in base else None
        mem = r["memory"]["total_nonaliased"] / 2**30
        fits = "yes" if mem <= 16.0 else "NO"
        rows.append((cell, rl["bottleneck"], rl["t_compute"], rl["t_memory"],
                     rl["t_collective"], frac, base_frac, mem, fits,
                     100 * rl["useful_flops_frac"]))
    rows.sort(key=lambda x: x[0])
    lines = [
        f"### Mesh `{mesh}`",
        "",
        "| cell | bottleneck | t_compute (s) | t_memory (s) | t_collective"
        " (s) | roofline frac | baseline frac | HBM GiB/chip | fits 16G |"
        " useful FLOPs |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        bf = f"{100*r[6]:.2f}%" if r[6] is not None else "—"
        lines.append(
            f"| {r[0]} | {r[1]} | {r[2]:.3e} | {r[3]:.3e} | {r[4]:.3e} |"
            f" {100*r[5]:.2f}% | {bf} | {r[7]:.2f} | {r[8]} |"
            f" {min(r[9], 999):.0f}% |")
    return "\n".join(lines)


def telemetry_section() -> str | None:
    """Markdown table for the fig_telemetry record in BENCH_dks.json —
    the measured cost of the always-on superstep counters.  Returns None
    when the file (or the fig — e.g. a pre-observability BENCH) is
    absent, so the report degrades instead of crashing."""
    path = HERE / "BENCH_dks.json"
    if not path.exists():
        return None
    bench = json.loads(path.read_text())
    fig = bench.get("telemetry")
    if not fig:
        return None
    lines = [
        "## Superstep telemetry overhead (fig_telemetry)",
        "",
        f"Fused loop with vs without the per-superstep counter carry "
        f"(`ExecutionPolicy(telemetry=True)`), commit "
        f"`{bench.get('commit', '?')}`; answers asserted bit-identical. "
        f"**Per-superstep ratio: {fig['per_superstep_ratio']:.3f}x** "
        f"(acceptance bar ~1.05x).",
        "",
        "| m | supersteps | base (s) | telemetry (s) | ratio |",
        "|---|---|---|---|---|",
    ]
    for q in fig.get("queries", []):
        lines.append(
            f"| {q['m']} | {q['supersteps']} | {q['base_s']:.4f} |"
            f" {q['telemetry_s']:.4f} | {q['ratio']:.3f} |")
    return "\n".join(lines)


def kernel_section() -> str | None:
    """Markdown table for the fig_lane_kernel record in
    BENCH_kernels.json — fused pallas lane-superstep kernel vs the
    vmapped jnp chain, per superstep.  Returns None when the file (a
    pre-kernel BENCH set, or a --only run that skipped it) is absent,
    so the report degrades instead of crashing."""
    path = HERE / "BENCH_kernels.json"
    if not path.exists():
        return None
    bench = json.loads(path.read_text())
    fig = bench.get("lane_kernel")
    if not fig:
        return None
    interp = fig.get("interpret")
    eqns = fig.get("jaxpr_eqns", {})
    lines = [
        "## Fused lane-superstep kernel (fig_lane_kernel)",
        "",
        f"One `pallas_call` per superstep "
        f"(vs {eqns.get('jnp', '?')} jaxpr equations on the jnp chain, "
        f"{eqns.get('pallas', '?')} fused), commit "
        f"`{bench.get('commit', '?')}`, platform "
        f"`{bench.get('platform', '?')}`"
        + (" — **interpret mode**: wall times measure the emulation, "
           "not the kernel; read the parity column and the equation "
           "counts, not the speedup." if interp else "."),
        "",
        "| lanes | jnp us/step | pallas us/step | speedup | parity |",
        "|---|---|---|---|---|",
    ]
    for r in fig.get("rows", []):
        lines.append(
            f"| {r['lanes']} | {r['jnp_us_per_step']} |"
            f" {r['pallas_us_per_step']} | {r['speedup']} |"
            f" {r['parity']} |")
    return "\n".join(lines)


def main():
    tel = telemetry_section()
    if tel:
        print(tel)
        print()
    ker = kernel_section()
    if ker:
        print(ker)
        print()
    base_s = load_dir(HERE / "dryrun_baseline" / "pod16x16")
    base_m = load_dir(HERE / "dryrun_baseline" / "multipod2x16x16")
    cur_s = load_dir(HERE / "dryrun" / "pod16x16")
    cur_m = load_dir(HERE / "dryrun" / "multipod2x16x16")
    print("## Auto-generated roofline tables (per-chip, TPU v5e constants)\n")
    print("`roofline frac` = analytic MODEL_FLOPS time / dominant roofline"
          " term; `baseline frac` = same for the pre-hillclimb build.\n")
    print(table(cur_s, base_s, "pod16x16 (single pod, 256 chips)"))
    print()
    print(table(cur_m, base_m, "multipod2x16x16 (2 pods, 512 chips)"))
    print()
    # Aggregates
    for name, cur, base in (("single-pod", cur_s, base_s),
                            ("multi-pod", cur_m, base_m)):
        if not cur:  # no dry-run JSONs checked in for this mesh
            print(f"- **{name}**: no dry-run data")
            continue
        fr = [roofline_frac(r) for r in cur.values()]
        common = [c for c in cur if c in base]
        gains = [roofline_frac(cur[c]) / max(roofline_frac(base[c]), 1e-12)
                 for c in common if roofline_frac(base[c]) > 0]
        fits = sum(1 for r in cur.values()
                   if r["memory"]["total_nonaliased"] / 2**30 <= 16.0)
        print(f"- **{name}**: {len(cur)} cells; median roofline frac "
              f"{100*sorted(fr)[len(fr)//2]:.2f}%; "
              f"{fits}/{len(cur)} fit 16 GiB HBM; median gain vs baseline "
              f"{sorted(gains)[len(gains)//2]:.2f}x over {len(gains)} cells")


if __name__ == "__main__":
    main()
