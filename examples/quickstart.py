"""Quickstart: the paper's Fig. 1 scenario in 40 lines.

Three "leads" (keyword groups) in a small call-record-style graph; DKS
finds the connection node and the minimal answer-tree, and we verify it
against the exact Dreyfus-Wagner oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import DKSConfig, dreyfus_wagner, extract_answers, run_dks
from repro.graph.structure import build_graph

# A small entity graph: node 7 is the hidden hub connecting all three leads.
edges = [
    (0, 7), (1, 7), (2, 7),          # leads' phones -> hub
    (3, 0), (4, 1), (5, 2),          # peripheral entities
    (0, 1), (8, 9), (9, 2), (7, 8),  # noise / alternate paths
]
w = np.asarray([1, 1, 2, 1, 1, 1, 5, 1, 3, 2], np.float32)
g = build_graph([e[0] for e in edges], [e[1] for e in edges], 10, w=w)

# Query: one keyword per lead; keyword-nodes per group.
groups = [[0, 3], [1, 4], [2, 5]]
masks = np.zeros((3, g.n_nodes), bool)
for i, grp in enumerate(groups):
    masks[i, grp] = True

cfg = DKSConfig(m=3, k=2)
state = run_dks(g.to_device(), jnp.asarray(masks), cfg)

print(f"supersteps: {int(state.step)}  (early exit: {bool(state.done)})")
print(f"top-{cfg.k} answer weights: "
      f"{[float(x) for x in state.topk_w if x < 1e8]}")

answers = extract_answers(np.asarray(state.S), g, masks, k=2)
for i, a in enumerate(answers):
    print(f"answer #{i+1}: root={a.root} weight={a.weight} edges={a.edges}")

opt = dreyfus_wagner(g, groups)
assert abs(answers[0].weight - opt) < 1e-6, (answers[0].weight, opt)
print(f"verified optimal (Dreyfus-Wagner oracle: {opt})")

# The same flow through the QueryEngine facade (the production front door):
# build once, then every query is index lookup + cached compiled executors.
from repro.engine import QueryEngine  # noqa: E402

g.labels = ["alice phone", "bob phone", "carol phone", "alice", "bob",
            "carol", "unused", "hub", "relay", "relay two"]
engine = QueryEngine.build(g)
result = engine.query(["alice", "bob", "carol"], k=2)
print(f"\nengine: best weight {result.best.weight} at root "
      f"{result.best.root} in {result.supersteps} supersteps")
assert abs(result.best.weight - opt) < 1e-6
