"""Serving demo: DKSService in front of QueryEngine — concurrent clients
coalesced by the micro-batcher, repeat queries served from the LRU result
cache, and a deadline-bounded query answered best-so-far with its SPA
lower bound (the paper's Sec. 5.4 early-termination guarantee as a
serving feature).

    PYTHONPATH=src python examples/serving.py [--dataset sec-rdfabout-cpu]
"""

import argparse

from repro.engine import ExecutionPolicy
from repro.launch.dks_query import build_engine
from repro.serve import DKSService, ServeConfig
from repro.serve.loadgen import make_trace, replay

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="sec-rdfabout-cpu")
ap.add_argument("--requests", type=int, default=16)
ap.add_argument("--clients", type=int, default=8)
args = ap.parse_args()

ds, engine = build_engine(
    args.dataset, ExecutionPolicy(max_supersteps=16))
print(f"graph: {ds.name} V={engine.n_nodes:,} E_sym={engine.n_edges:,}")

trace = make_trace(engine.index, args.requests, unique=4, seed=7)
with DKSService(engine,
                ServeConfig(max_batch=4, max_wait_ms=25.0,
                            cache_size=64)) as svc:
    served = replay(svc, trace, n_clients=args.clients)
    for i, (req, srv) in enumerate(zip(trace, served)):
        src = "cache" if srv.cache_hit else f"batch[{srv.batch_size}]"
        best = srv.best_weight if srv.found else None
        print(f"q{i:02d} m={len(req.keywords)} {src:9s} "
              f"{srv.latency_ms:7.1f} ms  best={best}")

    # Deadline-bounded: the budget expires mid-run, the client still gets
    # ranked best-so-far answers plus a lower bound on the optimum.
    svc.invalidate_cache()
    q = list(trace[0].keywords)
    bounded = svc.query(q, k=1, deadline_ms=5.0)
    best = bounded.best_weight if bounded.found else None
    if bounded.approximate:
        print(f"\ndeadline 5ms on {q}: approximate, best-so-far={best}, "
              f"optimum >= {bounded.opt_lower_bound} "
              f"(sound: {bounded.sound_opt_lower_bound})")
    else:
        print(f"\ndeadline 5ms on {q}: finished inside the budget, "
              f"exact best={best}")

    print("\n--- ServeStats ---")
    print(svc.stats().summary())
