"""Train a reduced LM end-to-end with the production stack: sharded-state
trainer, prefetching pipeline, fault guard, async checkpointing — then
kill it mid-run and prove checkpoint/restart resumes losslessly.

    PYTHONPATH=src python examples/train_lm.py
(on a pod the same driver trains the full config:
 python -m repro.launch.train --arch qwen1.5-4b --steps 1000 ...)
"""

import shutil
import sys
import tempfile
from pathlib import Path

sys.argv = [sys.argv[0]]  # keep argparse in train.py quiet

from repro.launch.train import train_lm  # noqa: E402


class Args:
    arch = "qwen1.5-4b"
    steps = 60
    batch = 8
    seq = 64
    lr = 1e-3
    grad_accum = 1
    seed = 0
    smoke = True
    ckpt_dir: str | None = None
    ckpt_every = 20
    log_every = 10


tmp = Path(tempfile.mkdtemp(prefix="dks_lm_ckpt_"))
try:
    # Phase 1: train 35 steps, checkpoints at 20 (then killed "mid-run").
    a = Args()
    a.ckpt_dir = str(tmp)
    a.steps = 35
    out1 = train_lm(a)
    print("phase-1:", out1)

    # Phase 2: restart the same job; it resumes from the last checkpoint
    # and finishes the full 60 steps.
    b = Args()
    b.ckpt_dir = str(tmp)
    b.steps = 60
    out2 = train_lm(b)
    print("phase-2 (resumed):", out2)
    assert out2["last_loss"] < out1["first_loss"], "training did not improve"
    print("OK: loss improved across restart "
          f"({out1['first_loss']:.3f} -> {out2['last_loss']:.3f})")
finally:
    shutil.rmtree(tmp, ignore_errors=True)
