"""Train every assigned GNN architecture on a cora-like synthetic graph
(full-batch) and gin/schnet additionally on batched molecules — the same
``GraphBatch``/segment-op substrate the DKS engine uses.

    PYTHONPATH=src python examples/gnn_train.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.models import gnn as gnn_lib
from repro.optim import AdamWConfig, adamw_init, adamw_update


def cora_like(n=400, e=1600, d_feat=32, n_classes=7, seed=0):
    rng = np.random.default_rng(seed)
    # Features correlated with labels so training can succeed.
    labels = rng.integers(0, n_classes, n)
    centers = rng.normal(size=(n_classes, d_feat))
    x = centers[labels] + 0.5 * rng.normal(size=(n, d_feat))
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    return gnn_lib.GraphBatch(
        x=jnp.asarray(x, jnp.float32),
        edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        node_mask=jnp.ones(n, bool), edge_mask=jnp.ones(e, bool),
        labels=jnp.asarray(labels, jnp.int32),
        graph_ids=jnp.zeros(n, jnp.int32),
        positions=jnp.asarray(rng.normal(size=(n, 3)) * 2, jnp.float32),
        n_graphs=1)


def molecules(n_graphs=32, atoms=12, seed=0):
    rng = np.random.default_rng(seed)
    n = n_graphs * atoms
    pos = rng.normal(size=(n, 3)) * 2
    # kNN-ish edges within each molecule.
    src, dst = [], []
    for gi in range(n_graphs):
        for i in range(atoms):
            for j in rng.choice(atoms, 3, replace=False):
                src.append(gi * atoms + i)
                dst.append(gi * atoms + int(j))
    z = rng.integers(1, 10, (n, 1)).astype(np.float32)
    energy = np.asarray([z[g * atoms:(g + 1) * atoms].sum() for g in
                         range(n_graphs)], np.float32) * 0.1
    return gnn_lib.GraphBatch(
        x=jnp.asarray(z), edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        node_mask=jnp.ones(n, bool),
        edge_mask=jnp.ones(len(src), bool),
        labels=jnp.asarray(energy),
        graph_ids=jnp.asarray(np.repeat(np.arange(n_graphs), atoms), jnp.int32),
        positions=jnp.asarray(pos, jnp.float32), n_graphs=n_graphs)


for arch in [a for a, e in ARCHS.items() if e.family == "gnn"]:
    cfg = get_arch(arch).config.smoke()
    batch = (molecules() if cfg.family == "schnet"
             else cora_like(n_classes=cfg.n_classes))
    d_in = batch.x.shape[1]
    params = gnn_lib.init_gnn(jax.random.PRNGKey(0), cfg, d_in=d_in)
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_lib.gnn_loss(p, batch, cfg))(params)
        params, opt, _ = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss

    losses = []
    for i in range(60):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    print(f"{arch:<10s} loss {losses[0]:8.4f} -> {losses[-1]:8.4f}  "
          f"({'OK' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
    assert losses[-1] < losses[0], arch
print("all GNN architectures train")
