"""End-to-end driver (the paper's production flow, Fig. 2c): serve a batch
of relationship queries against an LOD-scale synthetic graph through the
:class:`repro.engine.QueryEngine` facade.

The engine owns index lookup, mask padding, device residency, and the
compiled-executable cache; ``query_batch`` buckets the mixed 2-/3-keyword
workload by ``m`` and runs each bucket as one vmapped device program —
the full Sec. 7 experiment flow in three lines.

    PYTHONPATH=src python examples/relationship_queries.py [--dataset bluk-bnb-cpu]
"""

import argparse
import time

import numpy as np

from repro.engine import ExecutionPolicy, QueryEngine
from repro.launch.dks_query import load_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="sec-rdfabout-cpu")
ap.add_argument("--n-queries", type=int, default=6)
ap.add_argument("--k", type=int, default=2)
ap.add_argument("--budget", type=float, default=float("inf"))
args = ap.parse_args()

ds, g, index = load_dataset(args.dataset)
print(f"graph: {ds.name} V={g.n_nodes:,} E_sym={g.n_edges_sym:,}")

engine = QueryEngine.build(
    g, index=index,
    policy=ExecutionPolicy(max_supersteps=24, message_budget=args.budget))

# Build a mixed workload: 2- and 3-keyword queries across the df spectrum.
vocab = sorted(index.vocabulary(), key=index.df)
usable = [t for t in vocab if index.df(t) >= 2]
rng = np.random.default_rng(7)
queries = []
for i in range(args.n_queries):
    m = 2 + i % 2
    lo = int(len(usable) * (i / args.n_queries))
    picks = rng.choice(np.arange(lo, min(lo + 30, len(usable))), m,
                       replace=False)
    queries.append([usable[int(p)] for p in picks])

t0 = time.perf_counter()
results = engine.query_batch(queries, k=args.k)
total_t = time.perf_counter() - t0

for qi, res in enumerate(results):
    line = (f"q{qi} m={res.m} kw_nodes={res.kw_nodes:5d} "
            f"steps={res.supersteps:2d} "
            f"explored={100*res.explored_frac:5.1f}% ")
    if res.found:
        line += f"best={res.best.weight} root={res.best.root}"
        if res.budget_hit or res.capped:
            line += f" SPA-ratio={res.spa_ratio:.2f}"
    else:
        line += "no answer (disconnected leads)"
    print(line)

print(f"\nserved {len(queries)} queries in {total_t:.2f}s "
      f"({total_t/len(queries):.2f}s avg, "
      f"{engine.cache_stats['executables']} compiled programs)")
