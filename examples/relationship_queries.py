"""End-to-end driver (the paper's production flow, Fig. 2c): serve a batch
of relationship queries against an LOD-scale synthetic graph.

inverted-index lookup -> keyword masks -> jitted DKS while-loop ->
aggregator-side tree extraction, with per-query timing, early-exit stats
and SPA-ratio on budget-limited queries — the full Sec. 7 experiment flow.

    PYTHONPATH=src python examples/relationship_queries.py [--dataset bluk-bnb-cpu]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DKSConfig, extract_answers, run_dks
from repro.core.spa import spa_cover_dp, spa_ratio
from repro.launch.dks_query import load_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--dataset", default="sec-rdfabout-cpu")
ap.add_argument("--n-queries", type=int, default=6)
ap.add_argument("--k", type=int, default=2)
ap.add_argument("--budget", type=float, default=float("inf"))
args = ap.parse_args()

ds, g, index = load_dataset(args.dataset)
print(f"graph: {ds.name} V={g.n_nodes:,} E_sym={g.n_edges_sym:,}")
dg = g.to_device()

# Build a mixed workload: 2- and 3-keyword queries across the df spectrum.
vocab = sorted(index.vocabulary(), key=index.df)
usable = [t for t in vocab if index.df(t) >= 2]
rng = np.random.default_rng(7)
queries = []
for i in range(args.n_queries):
    m = 2 + i % 2
    lo = int(len(usable) * (i / args.n_queries))
    picks = rng.choice(np.arange(lo, min(lo + 30, len(usable))), m,
                       replace=False)
    queries.append([usable[int(p)] for p in picks])

total_t = 0.0
for qi, q in enumerate(queries):
    masks = index.keyword_masks(q, g.n_nodes)
    masks = np.pad(masks, ((0, 0), (0, dg.v_pad - g.n_nodes)))
    cfg = DKSConfig(m=len(q), k=args.k, max_supersteps=24,
                    message_budget=args.budget)
    t0 = time.perf_counter()
    state = jax.block_until_ready(run_dks(dg, jnp.asarray(masks), cfg))
    dt = time.perf_counter() - t0
    total_t += dt
    best = float(state.topk_w[0])
    line = (f"q{qi} m={len(q)} kw_nodes={int(masks.sum()):5d} "
            f"steps={int(state.step):2d} t={dt:6.2f}s "
            f"explored={100*float(jnp.mean(state.visited[:g.n_nodes])):5.1f}% ")
    if best < 1e8:
        answers = extract_answers(np.asarray(state.S), g,
                                  masks[:, : g.n_nodes], k=args.k)
        line += f"best={answers[0].weight} root={answers[0].root}"
        if bool(state.budget_hit):
            spa = spa_cover_dp(state.s_front + dg.e_min(), cfg.m)
            line += f" SPA-ratio={float(spa_ratio(state.topk_w[0], spa)):.2f}"
    else:
        line += "no answer (disconnected leads)"
    print(line)

print(f"\nserved {len(queries)} queries in {total_t:.2f}s "
      f"({total_t/len(queries):.2f}s avg)")
